"""Cluster serving layer (runtime/cluster.py, DESIGN.md §11 + §15).

Covers the router contract (token-identity vs a single engine for every
router, deterministic prefix-affinity placement under seeded traces), the
KV-migration lifecycle (block-table + payload copy, refcounts back to
zero on BOTH exporter and importer after finish and after cancels at
every migration stage, prefix re-registration and importer-side sharing),
fault injection proving the quiescence sweep catches a refcount-leaking
``import_blocks``, the §15 failure handling (kill a replica mid-prefill /
mid-migration / mid-decode — requeued requests finish token-identical to
a never-failed run, refcounts sweep to zero, lifecycle traces stay
valid, and a requeue that skips the KV release is CAUGHT), the loopback
wire (every envelope and payload through the frame codec), and the
multi-process socket cluster (real ``EngineHost`` workers, a hard kill
mid-run, requeue recovery over TCP).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.runtime.cluster import (ClusterConfig, ClusterServer,
                                   MigrationCost, Replica, ROUTERS)
from repro.runtime.engine import Engine
from repro.runtime.paging import BlockManager
from repro.runtime.requests import (Request, State, grouped_prefix_trace,
                                    poisson_arrivals)
from repro.runtime.scheduler import SchedulerConfig

_JIT_CACHES = {}


def _engine(tiny_model, **kw):
    api, mesh, params = tiny_model
    d = dict(max_batch=4, chunk_tokens=48, max_len=96, prefill_bucket=16,
             paged=True, block_size=8)
    d.update(kw)
    cache = _JIT_CACHES.setdefault(tuple(sorted(d.items())), {})
    return Engine(api, mesh, params, SchedulerConfig(**d), jit_cache=cache)


def _leak_sweep(eng):
    mgr = eng.block_mgr
    assert not mgr.tables, list(mgr.tables)
    leaked = [b for b in range(mgr.alloc.num_blocks) if mgr.alloc.ref[b]]
    assert not leaked, leaked


def _trace(n=6, seed=3, out=4, rate=0.5):
    rng = np.random.RandomState(seed)
    reqs = [Request(rid=i,
                    prompt=list(rng.randint(0, 128,
                                            size=rng.randint(10, 30))),
                    max_new_tokens=out) for i in range(n)]
    return poisson_arrivals(reqs, rate=rate, seed=seed)


# --------------------------------------------------------------------------
# BlockManager export/import (host-side, no device)
# --------------------------------------------------------------------------

def test_export_import_refcounts_and_prefix_reregistration():
    src = BlockManager(num_blocks=8, block_size=4, max_blocks_per_req=4)
    ctx = list(range(10))                       # 2 full blocks + tail
    assert src.allocate_prompt(1, ctx) == 0
    src.register_filled(1, ctx, 8)
    blocks = src.export_blocks(1, 10)
    assert blocks == src.tables[1][:3]
    src.free_request(1)
    assert not src.tables
    # exporter: registered full blocks park in the LRU (still hittable),
    # every refcount back to zero
    assert all(src.alloc.ref[b] == 0 for b in range(8))
    assert len(src.prefix) == 2

    dst = BlockManager(num_blocks=8, block_size=4, max_blocks_per_req=4)
    imported = dst.import_blocks(1, ctx, 10)
    assert imported is not None
    table, copy_idx = imported
    assert len(table) == 3 and copy_idx == [0, 1, 2]   # cold: all copied
    dst.register_filled(1, ctx, 10)
    assert len(dst.prefix) == 2                 # re-registered on importer
    assert dst.stats.migrations_in == 1
    # a second import of a shared-prefix context hits the importer's cache
    ctx2 = list(range(8)) + [99, 98]
    imported2 = dst.import_blocks(2, ctx2, 10)
    table2, copy_idx2 = imported2
    assert copy_idx2 == [2]                     # 2 full-block hits shared
    assert dst.alloc.ref[table2[0]] == 2 and dst.alloc.ref[table2[1]] == 2
    assert dst.stats.import_shared_blocks == 2
    dst.free_request(1)
    dst.free_request(2)
    assert all(dst.alloc.ref[b] == 0 for b in range(8))


def test_import_blocks_rolls_back_atomically_when_pool_too_small():
    dst = BlockManager(num_blocks=3, block_size=4, max_blocks_per_req=4)
    ctx = list(range(12))                       # needs 3 blocks + headroom
    assert dst.import_blocks(1, ctx, 12) is None
    assert not dst.tables
    assert all(dst.alloc.ref[b] == 0 for b in range(3))
    assert dst.alloc.num_available() == 3


# --------------------------------------------------------------------------
# engine-level handoff: park -> adopt -> decode resumes from migrated KV
# --------------------------------------------------------------------------

def test_handoff_token_identical_and_refcounts_zero_both_sides(tiny_model):
    prompt = list(np.random.RandomState(0).randint(0, 128, size=20))

    ref_eng = _engine(tiny_model)
    ref_eng.add_request(Request(rid=0, prompt=list(prompt),
                                max_new_tokens=6))
    ref = ref_eng.run()[0].output

    src = _engine(tiny_model)
    dst = _engine(tiny_model)
    req = Request(rid=0, prompt=list(prompt), max_new_tokens=6,
                  handoff_after_prefill=True)
    src.add_request(req)
    src.run()
    handoffs = src.take_handoffs()
    assert len(handoffs) == 1 and handoffs[0].req is req
    assert req.state == State.DECODE and len(req.output) == 1
    _leak_sweep(src)                        # exporter released everything
    h = handoffs[0]
    assert dst.adopt_request(h.req, h.n_tokens, h.payload)
    done = dst.run()
    assert done[0].output == ref            # decode resumed from migrated KV
    assert req.migrations == 1
    _leak_sweep(dst)
    _leak_sweep(src)


def test_adopt_request_returns_false_without_slot_or_blocks(tiny_model):
    src = _engine(tiny_model)
    req = Request(rid=7, prompt=list(range(20)), max_new_tokens=4,
                  handoff_after_prefill=True)
    src.add_request(req)
    src.run()
    h = src.take_handoffs()[0]

    # no free slot: fill the importer's slots first
    dst = _engine(tiny_model, max_batch=2)
    blockers = [Request(rid=i, prompt=list(range(1, 12)), max_new_tokens=64)
                for i in (1, 2)]
    for b in blockers:
        dst.add_request(b)
    while not all(b.state == State.DECODE for b in blockers):
        dst.step()
    assert not dst.adopt_request(h.req, h.n_tokens, h.payload)
    assert h.req.rid not in dst.block_mgr.tables   # nothing half-done

    # no blocks: a pool too small for the context
    tiny_pool = _engine(tiny_model, max_batch=2, num_blocks=3)
    assert not tiny_pool.adopt_request(h.req, h.n_tokens, h.payload)
    _leak_sweep(tiny_pool)


# --------------------------------------------------------------------------
# cluster: routing
# --------------------------------------------------------------------------

@pytest.mark.parametrize("router", sorted(ROUTERS))
def test_every_router_token_identical_to_single_engine(router, tiny_model):
    ref_eng = _engine(tiny_model)
    for r in _trace():
        ref_eng.add_request(r)
    ref = {r.rid: r.output for r in ref_eng.run()}

    reps = [Replica(f"r{i}", _engine(tiny_model)) for i in range(3)]
    cs = ClusterServer(reps, ClusterConfig(router=router))
    for r in _trace():
        cs.submit(r)
    done = cs.run()
    assert {r.rid: r.output for r in done} == ref
    cs.check_quiescent()


def _affinity_run(tiny_model):
    reps = [Replica(f"r{i}", _engine(tiny_model)) for i in range(3)]
    cs = ClusterServer(reps, ClusterConfig(router="prefix_affinity"))
    trace = grouped_prefix_trace(3, 4, prefix_len=24, tail_len=6,
                                 output_len=4, vocab=128, seed=3)
    for r in poisson_arrivals(trace, rate=0.4, seed=5):
        cs.submit(r)
    done = cs.run()
    cs.check_quiescent()
    return cs.placement, {r.rid: r.output for r in done}, cs


def test_prefix_affinity_deterministic_and_groups_stick(tiny_model):
    p1, out1, cs1 = _affinity_run(tiny_model)
    p2, out2, cs2 = _affinity_run(tiny_model)
    assert p1 == p2 and out1 == out2            # seeded trace -> replayable
    assert cs1.summary() == cs2.summary()
    assert cs1.stats.affinity_hit_rate > 0
    # once a group's first request warmed a replica, later group members
    # follow it (their shared prefix is hot exactly there)
    assert len(p1) == 12
    for rid in sorted(p1):
        if rid >= 3:                            # group seen before
            assert p1[rid] == p1[rid % 3], (rid, p1)


def test_least_loaded_prefers_idle_replica(tiny_model):
    reps = [Replica(f"r{i}", _engine(tiny_model)) for i in range(2)]
    cs = ClusterServer(reps, ClusterConfig(router="least_loaded"))
    # two simultaneous arrivals: the second must go to the other replica
    # (the first is queued there, its tokens counted by load())
    reqs = [Request(rid=i, prompt=list(range(1, 21)), max_new_tokens=32)
            for i in range(2)]
    for r in reqs:
        r.arrival_time = 0.0
        cs.submit(r)
    cs.run()
    cs.check_quiescent()
    assert reps[0].engine.stats.completed == 1
    assert reps[1].engine.stats.completed == 1


# --------------------------------------------------------------------------
# cluster: disaggregated prefill/decode migration lifecycle
# --------------------------------------------------------------------------

def _disagg(tiny_model, migration_base=1.0, decode_kw=None):
    reps = [Replica("p0", _engine(tiny_model), role="prefill"),
            Replica("d0", _engine(tiny_model, **(decode_kw or {})),
                    role="decode")]
    cfg = ClusterConfig(router="round_robin",
                        migration_cost=MigrationCost(base=migration_base))
    return reps, ClusterServer(reps, cfg)


def test_disagg_token_identical_with_migration_latency(tiny_model):
    ref_eng = _engine(tiny_model)
    for r in _trace(n=5):
        ref_eng.add_request(r)
    ref = {r.rid: r.output for r in ref_eng.run()}

    reps, cs = _disagg(tiny_model, migration_base=7.5)
    for r in _trace(n=5):
        cs.submit(r)
    done = cs.run()
    assert {r.rid: r.output for r in done} == ref
    assert cs.summary()["migrations"] == 5
    assert all(r.migrations == 1 for r in done)
    assert reps[1].engine.block_mgr.stats.migrations_in == 5
    cs.check_quiescent()


def test_cancel_mid_migration_releases_both_sides(tiny_model):
    # a huge migration latency parks the handoff in the decode replica's
    # adoption queue; the cancel lands while the KV is "on the wire"
    reps, cs = _disagg(tiny_model, migration_base=1000.0)
    req = Request(rid=0, prompt=list(range(1, 21)), max_new_tokens=8)
    req.arrival_time = 0.0
    cs.submit(req)
    cs.cancel(0, at=50.0)
    done = cs.run()
    assert done == [] and cs.aborted == [req]
    assert req.finish_reason == "cancelled"
    assert cs.stats.migrations_started == 1     # export happened...
    assert cs.summary()["migrations"] == 0      # ...but it never completed
    assert reps[1].engine.block_mgr.stats.migrations_in == 0
    cs.check_quiescent()                        # zero refs on BOTH sides


def test_cancel_after_adoption_releases_importer(tiny_model):
    reps, cs = _disagg(tiny_model)
    req = Request(rid=0, prompt=list(range(1, 21)), max_new_tokens=500)
    req.arrival_time = 0.0
    cs.submit(req)
    cs.cancel(0, at=30.0)                       # long after adoption
    done = cs.run()
    assert done == [] and req.finish_reason == "cancelled"
    assert reps[1].engine.block_mgr.stats.migrations_in == 1
    assert reps[1].engine.stats.cancelled == 1
    cs.check_quiescent()


def test_cancel_before_routing_never_reaches_any_replica(tiny_model):
    reps, cs = _disagg(tiny_model)
    req = Request(rid=0, prompt=list(range(1, 9)), max_new_tokens=4)
    req.arrival_time = 10.0
    cs.submit(req)
    cs.cancel(0, at=5.0)
    assert cs.run() == []
    assert req.finish_reason == "cancelled"
    assert all(r.engine.stats.steps == 0 for r in reps)
    cs.check_quiescent()


def test_adoption_head_of_line_blocks_until_slot_frees(tiny_model):
    # decode replica with 2 slots, 3 migrated requests: the third adoption
    # must wait for a slot, then land and finish — nobody starves
    reps, cs = _disagg(tiny_model, decode_kw=dict(max_batch=2))
    for r in _trace(n=3, out=8, rate=5.0):
        cs.submit(r)
    done = cs.run()
    assert len(done) == 3
    assert cs.summary()["migrations"] == 3
    cs.check_quiescent()


def test_explicit_replica_step_cost_survives_cluster_default(tiny_model):
    from repro.runtime.server import StepCost
    slow = StepCost(base=2.0)
    reps = [Replica("a", _engine(tiny_model), step_cost=slow),
            Replica("b", _engine(tiny_model))]
    ClusterServer(reps, ClusterConfig())
    assert reps[0].step_cost is slow            # heterogeneous fleet kept
    assert reps[1].step_cost is not None        # default filled in


def test_disagg_requires_paged_backend(tiny_model):
    api, mesh, params = tiny_model
    legacy = Engine(api, mesh, params,
                    SchedulerConfig(max_batch=4, chunk_tokens=48,
                                    max_len=96, prefill_bucket=16,
                                    paged=False))
    reps = [Replica("p0", legacy, role="prefill"),
            Replica("d0", _engine(tiny_model), role="decode")]
    with pytest.raises(ValueError, match="paged"):
        ClusterServer(reps, ClusterConfig())


def test_disagg_roles_validated(tiny_model):
    with pytest.raises(ValueError, match="prefill AND one decode"):
        ClusterServer([Replica("p0", _engine(tiny_model), role="prefill")],
                      ClusterConfig())
    with pytest.raises(ValueError, match="mixed"):
        ClusterServer([Replica("p0", _engine(tiny_model), role="prefill"),
                       Replica("d0", _engine(tiny_model), role="decode"),
                       Replica("m0", _engine(tiny_model))],
                      ClusterConfig())


# --------------------------------------------------------------------------
# fault injection: the quiescence sweep must BITE
# --------------------------------------------------------------------------

def test_refcount_leaking_import_blocks_is_caught(tiny_model):
    reps, cs = _disagg(tiny_model)
    mgr = reps[1].engine.block_mgr
    real_import = mgr.import_blocks

    def leaky_import(rid, context, n_tokens, **kw):
        out = real_import(rid, context, n_tokens, **kw)
        if out is not None:
            table, _ = out
            mgr.alloc.share(table[0])           # the leak: an extra ref
        return out

    mgr.import_blocks = leaky_import
    for r in _trace(n=2):
        cs.submit(r)
    cs.run()
    with pytest.raises(AssertionError):
        cs.check_quiescent()


def test_decref_skipping_free_request_is_caught(tiny_model):
    reps, cs = _disagg(tiny_model)
    mgr = reps[1].engine.block_mgr

    def broken_free(rid):
        mgr.tables.pop(rid, None)               # forgets every decref
        mgr._reg_cursor.pop(rid, None)

    mgr.free_request = broken_free
    for r in _trace(n=2):
        cs.submit(r)
    cs.run()
    with pytest.raises(AssertionError):
        cs.check_quiescent()


# --------------------------------------------------------------------------
# failure handling (DESIGN.md §15): kill -> heartbeat-timeout detect ->
# requeue on survivors, token-identical to a never-failed run
# --------------------------------------------------------------------------

def _reference(tiny_model, trace):
    ref_eng = _engine(tiny_model)
    for r in trace:
        ref_eng.add_request(r)
    return {r.rid: r.output for r in ref_eng.run()}


def test_kill_mid_decode_requeues_token_identical(tiny_model):
    ref = _reference(tiny_model, _trace(n=6))

    reps = [Replica(f"r{i}", _engine(tiny_model)) for i in range(2)]
    cs = ClusterServer(reps, ClusterConfig(router="round_robin"))
    for r in _trace(n=6):
        cs.submit(r)
    cs.kill_replica("r0", at=8.0)       # mid-run: r0 owns decoding work
    done = cs.run()
    assert {r.rid: r.output for r in done} == ref
    assert cs.stats.replica_deaths == 1
    assert cs.stats.requeued >= 1
    requeued = [r for r in done if r.requeues]
    assert requeued and all(r.requeues == 1 for r in requeued)
    assert all(cs.placement[r.rid] == "r1" for r in requeued)
    cs.check_quiescent()                # dead replica swept clean too
    assert not reps[0].alive and reps[1].alive


def test_kill_mid_prefill_requeues_token_identical(tiny_model):
    # long prompts + a kill right after the first tick: r0 dies while its
    # requests are still chunk-prefilling (no output yet)
    trace = [Request(rid=i, prompt=list(range(1, 81)), max_new_tokens=4,
                     arrival_time=0.0) for i in range(2)]
    ref = _reference(tiny_model, [Request(rid=r.rid, prompt=list(r.prompt),
                                          max_new_tokens=4) for r in trace])

    reps = [Replica(f"r{i}", _engine(tiny_model)) for i in range(2)]
    cs = ClusterServer(reps, ClusterConfig(router="round_robin"))
    for r in trace:
        cs.submit(r)
    cs.kill_replica("r0", at=1.5)
    done = cs.run()
    assert {r.rid: r.output for r in done} == ref
    killed = [r for r in done if r.requeues]
    assert killed and all(not r.resumed or r.output for r in killed)
    cs.check_quiescent()


def test_kill_decode_replica_mid_migration(tiny_model):
    # a slow wire parks the handoff in d0's adoption queue; d0 dies with
    # the KV "in flight" — the request re-prefills via ingress and
    # migrates to d1 instead, token-identical
    reps = [Replica("p0", _engine(tiny_model), role="prefill"),
            Replica("d0", _engine(tiny_model), role="decode"),
            Replica("d1", _engine(tiny_model), role="decode")]
    cfg = ClusterConfig(router="round_robin",
                        migration_cost=MigrationCost(base=50.0))
    cs = ClusterServer(reps, cfg)
    req = Request(rid=0, prompt=list(range(1, 21)), max_new_tokens=6,
                  arrival_time=0.0)
    ref = _reference(tiny_model, [Request(rid=0, prompt=list(range(1, 21)),
                                          max_new_tokens=6)])
    cs.submit(req)
    cs.kill_replica("d0", at=10.0)      # while the handoff rides the wire
    done = cs.run()
    assert {r.rid: r.output for r in done} == ref
    assert req.requeues == 1 and req.migrations == 1
    assert cs.stats.migrations_started == 2      # first one died in flight
    assert reps[2].engine.block_mgr.stats.migrations_in == 1
    assert reps[1].engine.block_mgr.stats.migrations_in == 0
    cs.check_quiescent()


def test_kill_strands_detection_window_arrivals(tiny_model):
    # a request routed to a dead-but-undetected replica waits out the
    # heartbeat timeout in its queue, then recovers on the survivor
    reps = [Replica(f"r{i}", _engine(tiny_model)) for i in range(2)]
    cs = ClusterServer(reps, ClusterConfig(
        router="round_robin", heartbeat_timeout=5.0))
    r0 = Request(rid=0, prompt=list(range(1, 11)), max_new_tokens=2,
                 arrival_time=0.0)
    r1 = Request(rid=1, prompt=list(range(1, 11)), max_new_tokens=2,
                 arrival_time=1.0)   # round-robin -> lands on dead r1
    cs.submit(r0)
    cs.submit(r1)
    cs.kill_replica("r1", at=0.5)
    done = cs.run()
    assert len(done) == 2
    assert r1.requeues == 1 and r1.admit_time >= 0.5 + 5.0
    cs.check_quiescent()


def test_kill_after_finish_is_harmless(tiny_model):
    reps = [Replica(f"r{i}", _engine(tiny_model)) for i in range(2)]
    cs = ClusterServer(reps, ClusterConfig())
    for r in _trace(n=2):
        cs.submit(r)
    cs.kill_replica("r0", at=10_000.0)  # long after the trace drains
    done = cs.run()
    assert len(done) == 2 and all(not r.requeues for r in done)
    assert cs.stats.replica_deaths == 1 and cs.stats.requeued == 0
    cs.check_quiescent()


def test_kill_unknown_replica_rejected(tiny_model):
    cs = ClusterServer([Replica("r0", _engine(tiny_model))], ClusterConfig())
    with pytest.raises(ValueError, match="unknown replica"):
        cs.kill_replica("nope", at=1.0)


def test_requeue_lifecycle_trace_valid(tiny_model):
    from repro.obs.trace import (TraceRecorder, export_chrome_trace,
                                 validate_chrome_trace)
    api, mesh, params = tiny_model
    rec = TraceRecorder()
    engines = [Engine(api, mesh, params,
                      SchedulerConfig(max_batch=4, chunk_tokens=48,
                                      max_len=96, prefill_bucket=16,
                                      paged=True, block_size=8),
                      obs=rec) for _ in range(2)]
    reps = [Replica(f"r{i}", e) for i, e in enumerate(engines)]
    cs = ClusterServer(reps, ClusterConfig(router="round_robin"))
    for r in _trace(n=4, out=24):
        cs.submit(r)
    cs.kill_replica("r0", at=1.0)       # r0 still owns admitted work
    done = cs.run()
    assert len(done) == 4
    assert cs.stats.requeued >= 1       # the fault actually displaced work
    doc = export_chrome_trace(rec)
    assert validate_chrome_trace(doc) == []
    phases = [e["name"] for e in doc["traceEvents"]
              if e.get("cat") == "request"]
    assert "requeue" in phases          # the §15 lifecycle event exists
    cs.check_quiescent()


def test_leaky_evacuate_is_caught_by_quiescence_sweep(tiny_model):
    # a requeue that hands the requests back but SKIPS the KV release:
    # the survivors still finish token-identical, but the dead replica's
    # pool is left holding refs — check_quiescent must bite
    from repro.runtime.requests import reset_for_requeue
    ref = _reference(tiny_model, _trace(n=4, out=24))

    reps = [Replica(f"r{i}", _engine(tiny_model)) for i in range(2)]
    cs = ClusterServer(reps, ClusterConfig(router="round_robin"))
    eng0 = reps[0].engine

    def leaky_evacuate():
        out = [r for r in list(eng0.sched.waiting)
               + [x for x in eng0.sched.active if x is not None]]
        eng0.sched.waiting = []
        eng0.sched.active = [None] * len(eng0.sched.active)
        return [reset_for_requeue(r) for r in out]   # blocks never freed

    eng0.evacuate = leaky_evacuate
    for r in _trace(n=4, out=24):
        cs.submit(r)
    cs.kill_replica("r0", at=1.0)       # r0 still holds active slots
    done = cs.run()
    assert {r.rid: r.output for r in done} == ref    # recovery still works
    assert cs.stats.requeued >= 1
    with pytest.raises(AssertionError):
        cs.check_quiescent()                         # ...but the leak bites


# --------------------------------------------------------------------------
# loopback wire (DESIGN.md §15): every envelope and KV payload through
# the frame codec, deterministically
# --------------------------------------------------------------------------

def test_wire_loopback_disagg_token_identical(tiny_model):
    ref = _reference(tiny_model, _trace(n=5))

    reps = [Replica("p0", _engine(tiny_model), role="prefill"),
            Replica("d0", _engine(tiny_model), role="decode")]
    cs = ClusterServer(reps, ClusterConfig(
        router="round_robin", wire="loopback", wire_per_byte=1e-6))
    for r in _trace(n=5):
        cs.submit(r)
    done = cs.run()
    assert {r.rid: r.output for r in done} == ref
    assert cs.summary()["migrations"] == 5
    snap = cs.metrics_snapshot()
    # 5 submit envelopes + 5 KV handoffs crossed the codec
    assert snap["cluster/wire/frames"] == 10
    assert snap["cluster/wire/bytes"] > 0
    assert snap["cluster/wire/frame_bytes/count"] == 10
    assert cs.wire.frames == 10
    cs.check_quiescent()


def test_wire_loopback_matches_wireless_cluster(tiny_model):
    def run(wire):
        reps = [Replica("p0", _engine(tiny_model), role="prefill"),
                Replica("d0", _engine(tiny_model), role="decode")]
        cs = ClusterServer(reps, ClusterConfig(router="round_robin",
                                               wire=wire))
        for r in _trace(n=4):
            cs.submit(r)
        done = cs.run()
        cs.check_quiescent()
        return {r.rid: r.output for r in done}

    assert run(None) == run("loopback")    # codec is a pure carrier


def test_wire_loopback_with_kill_recovers(tiny_model):
    ref = _reference(tiny_model, _trace(n=4))
    reps = [Replica(f"r{i}", _engine(tiny_model)) for i in range(2)]
    cs = ClusterServer(reps, ClusterConfig(router="round_robin",
                                           wire="loopback"))
    for r in _trace(n=4):
        cs.submit(r)
    cs.kill_replica("r1", at=6.0)
    done = cs.run()
    assert {r.rid: r.output for r in done} == ref
    assert cs.stats.replica_deaths == 1
    cs.check_quiescent()


def test_unknown_wire_mode_rejected(tiny_model):
    with pytest.raises(ValueError, match="wire mode"):
        ClusterServer([Replica("r0", _engine(tiny_model))],
                      ClusterConfig(wire="carrier-pigeon"))


# --------------------------------------------------------------------------
# multi-process socket cluster (slow): real EngineHost workers over TCP,
# a hard kill mid-run, requeue recovery through the same codec
# --------------------------------------------------------------------------

def _spawn_worker(name, spec=None):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    argv = [sys.executable, "-m", "repro.runtime.transport",
            "--port", "0", "--name", name]
    if spec:
        argv += ["--spec", json.dumps(spec)]
    proc = subprocess.Popen(argv, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    line = proc.stdout.readline()
    assert line.startswith("LISTENING"), (line, proc.stderr.read()[-2000:])
    _, host, port = line.split()
    return proc, host, int(port)


@pytest.mark.slow
def test_socket_cluster_kill_and_requeue_token_identical(tiny_model):
    from repro.runtime.transport import RemoteEngine

    # DEFAULT_SPEC workers == the tiny fixture model/scheduler, so the
    # in-process reference is the socket fleet's never-failed twin
    ref = _reference(tiny_model, _trace(n=6))

    procs, remotes = [], []
    try:
        for name in ("w0", "w1"):
            proc, host, port = _spawn_worker(name)
            procs.append(proc)
            remotes.append(RemoteEngine(host, port, name=name, timeout=300))
        reps = [Replica(f"r{i}", rem) for i, rem in enumerate(remotes)]
        cs = ClusterServer(reps, ClusterConfig(router="round_robin"))
        for r in _trace(n=6):
            cs.submit(r)
        # hard-kill w0 (os._exit before the reply) a few steps in: the
        # frontend sees ReplicaGone on that RPC, detects, requeues on w1
        remotes[0].die_after(4)
        done = cs.run()
        assert {r.rid: r.output for r in done} == ref
        assert cs.stats.replica_deaths == 1
        assert cs.stats.requeued >= 1
        assert any(r.requeues == 1 for r in done)
        assert not reps[0].alive and reps[1].alive
        cs.check_quiescent()            # w1 sweeps host-side via RPC
        assert procs[0].wait(timeout=60) == 17   # the injected hard exit
    finally:
        for rem in remotes:
            try:
                rem.close()
            except Exception:
                pass
        for proc in procs:
            proc.kill()
            proc.wait(timeout=30)
