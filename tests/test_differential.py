"""Randomized differential test harness (hypothesis-style but fully
deterministic, like test_splitting_props.py).

Seeded random traces — mixed prefill lengths, prefix-shared prompts,
spec-decode windows (γ ∈ {0..3}), and mid-flight cancellations — are
replayed through FIVE engine configurations:

  * two-dispatch over the paged block pool,
  * packed hybrid batching over the paged block pool,
  * two-dispatch over legacy slots,
  * packed over the paged pool with an all-``fused`` overlap plan (the
    ring AllReduce-RMSNorm hot path, DESIGN.md §2/§14),
  * two-dispatch over legacy slots with the same fused plan,

asserting greedy token-IDENTITY across all five for every surviving
request — packed-fused vs packed-weave vs two-dispatch, on both KV
backends — plus invariant sweeps at every step and at end of trace:

  * ``PackedPlan.total_tokens <= chunk_tokens`` (the §6 budget),
  * a cache slot is only ever reassigned after its owner finished,
  * block refcounts return to zero and every table is released.

The harness must CATCH faults, not just pass: the last tests inject a
skipped block release / a budget overrun and assert the sweep trips.
"""
import numpy as np
import pytest

from repro.runtime.paging import BlockManager
from repro.runtime.requests import Request, State
from repro.runtime.scheduler import PackedPlan

N_TRACES = 25


@pytest.fixture(scope="session")
def fused_plan_path(tmp_path_factory):
    """An overlap plan forcing method=``fused`` (ring kernel + weave,
    half the ring-lane budget) at EVERY tiny/tp1 site and bucket, so the
    fused engine columns exercise the plan-forced ring comm path — which
    on this backend walks the fallback ladder, and must stay
    token-identical either way."""
    from repro.core.policy import PLAN_VERSION, PlanEntry, SITES, TunedPolicy
    from repro.core.splitting import DEFAULT_BUCKET_EDGES, token_bucket
    buckets = {token_bucket(lo, DEFAULT_BUCKET_EDGES)
               for lo in DEFAULT_BUCKET_EDGES} | {token_bucket(0)}
    entries = tuple(PlanEntry(site=site, bucket=b, tp=1, family="dense",
                              method="fused", split_frac=0.5, budget=0.5)
                    for site in SITES for b in sorted(buckets))
    plan = TunedPolicy(plan_id=424242, version=PLAN_VERSION,
                       bucket_edges=DEFAULT_BUCKET_EDGES, entries=entries)
    path = tmp_path_factory.mktemp("plans") / "all_fused.json"
    plan.save(str(path))
    return str(path)


# --------------------------------------------------------------------------
# trace generation
# --------------------------------------------------------------------------

def _gen_trace(rng: np.random.RandomState):
    """One random workload: prompts (some sharing a random prefix),
    output budgets, a spec-decode gamma, and cancellation triggers
    ``rid -> n_tokens`` (cancel once the request has emitted n tokens —
    a per-request progress point, so the trigger is meaningful in every
    engine no matter how iterations interleave)."""
    n_req = int(rng.randint(2, 6))
    shared = list(rng.randint(0, 128, size=int(rng.randint(8, 24)))) \
        if rng.rand() < 0.5 else []
    prompts = []
    for _ in range(n_req):
        tail = list(rng.randint(0, 128, size=int(rng.randint(1, 40))))
        use_shared = shared and rng.rand() < 0.6
        prompts.append((shared + tail if use_shared else tail)[:96])
    outs = [int(rng.randint(2, 7)) for _ in range(n_req)]
    gamma = int(rng.choice([0, 0, 2, 3]))
    cancels = {}
    if rng.rand() < 0.4:
        rid = int(rng.randint(0, n_req))
        # 0 = cancel while still waiting/prefilling; >0 = mid-decode
        cancels[rid] = int(rng.randint(0, outs[rid]))
    return prompts, outs, gamma, cancels


# --------------------------------------------------------------------------
# instrumented driver
# --------------------------------------------------------------------------

def _drive(eng, prompts, outs, cancels, max_steps=500):
    """Step the engine manually with invariant checks woven between
    steps; apply cancellations when their progress trigger fires.
    Returns ``{rid: output}`` for requests that were not cancelled."""
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts, outs))]
    for r in reqs:
        eng.add_request(r)

    orig_next = eng.sched.next_step

    def checked_next():
        plan = orig_next()
        if isinstance(plan, PackedPlan):
            assert plan.total_tokens <= eng.scfg.chunk_tokens, (
                f"packed budget violated: {plan.total_tokens} > "
                f"{eng.scfg.chunk_tokens}")
            assert plan.total_tokens == sum(s.n_tokens
                                            for s in plan.segments)
            slots = [s.req.slot for s in plan.segments]
            assert len(set(slots)) == len(slots)
        return plan

    eng.sched.next_step = checked_next

    slot_owner = {}           # slot -> Request that last held it
    pending_cancel = dict(cancels)
    for _ in range(max_steps):
        for rid, trigger in list(pending_cancel.items()):
            r = reqs[rid]
            if r.state != State.DONE and len(r.output) >= trigger:
                eng.abort(r)
                del pending_cancel[rid]
        if not eng.step():
            break
        # slot-reuse sweep: a slot changes hands only after its previous
        # owner reached a terminal state
        for slot, r in enumerate(eng.sched.active):
            if r is None:
                continue
            prev = slot_owner.get(slot)
            if prev is not None and prev is not r:
                assert prev.state == State.DONE, (
                    f"slot {slot} reassigned from live rid {prev.rid}")
            slot_owner[slot] = r
    assert eng.sched.all_done(), "trace did not drain"
    _check_end_state(eng)
    return {r.rid: r.output for r in reqs if r.rid not in cancels}


def _check_end_state(eng):
    """End-of-trace resource sweep."""
    if eng.block_mgr is None:
        return
    mgr = eng.block_mgr
    assert not mgr.tables, f"unreleased block tables: {list(mgr.tables)}"
    leaked = [b for b in range(mgr.alloc.num_blocks) if mgr.alloc.ref[b]]
    assert not leaked, f"blocks with nonzero refcount after drain: {leaked}"


# --------------------------------------------------------------------------
# the differential sweep
# --------------------------------------------------------------------------

@pytest.mark.parametrize("trial", range(N_TRACES))
def test_differential_trace(trial, tiny_engine_builder, fused_plan_path):
    rng = np.random.RandomState(1000 + trial)
    prompts, outs, gamma, cancels = _gen_trace(rng)
    kw = dict(max_batch=3, chunk_tokens=48, max_len=128, prefill_bucket=16,
              block_size=16, spec_gamma=gamma)

    results = {}
    for name, cfg in (
            ("two_paged", dict(paged=True, packed=False)),
            ("packed_paged", dict(paged=True, packed=True)),
            ("two_legacy", dict(paged=False, packed=False)),
            # the fused-path columns: the same traces with the all-fused
            # overlap plan installed, on both KV backends
            ("packed_fused", dict(paged=True, packed=True,
                                  plan_path=fused_plan_path)),
            ("two_legacy_fused", dict(paged=False, packed=False,
                                      plan_path=fused_plan_path))):
        eng = tiny_engine_builder(**kw, **cfg)
        results[name] = _drive(eng, prompts, outs, cancels)

    ref = results["two_paged"]
    for name in ("packed_paged", "two_legacy", "packed_fused",
                 "two_legacy_fused"):
        assert results[name] == ref, (
            trial, gamma, cancels, name, results[name], ref)
    # every surviving request ran to its full budget
    for rid, out in ref.items():
        assert len(out) == outs[rid]


# --------------------------------------------------------------------------
# wire-cluster column (DESIGN.md §15): the same traces through a single
# engine, an in-process cluster, and a loopback-wire cluster whose every
# envelope (and, disaggregated, every KV payload) crosses the frame codec
# --------------------------------------------------------------------------

def _drive_cluster(engines, prompts, outs, roles=None, wire=None):
    from repro.runtime.cluster import ClusterConfig, ClusterServer, Replica
    roles = roles or ["mixed"] * len(engines)
    reps = [Replica(f"r{i}", e, role=role)
            for i, (e, role) in enumerate(zip(engines, roles))]
    cs = ClusterServer(reps, ClusterConfig(router="round_robin", wire=wire))
    for i, (p, n) in enumerate(zip(prompts, outs)):
        cs.submit(Request(rid=i, prompt=list(p), max_new_tokens=n,
                          arrival_time=0.25 * i))
    done = cs.run()
    cs.check_quiescent()
    return {r.rid: r.output for r in done}


@pytest.mark.parametrize("paged", [True, False], ids=["paged", "legacy"])
@pytest.mark.parametrize("trial", range(N_TRACES))
def test_differential_wire_cluster(trial, paged, tiny_engine_builder):
    rng = np.random.RandomState(3000 + trial)
    prompts, outs, _, _ = _gen_trace(rng)
    kw = dict(max_batch=3, chunk_tokens=48, max_len=128, prefill_bucket=16,
              block_size=16, paged=paged)

    single = tiny_engine_builder(**kw)
    for i, (p, n) in enumerate(zip(prompts, outs)):
        single.add_request(Request(rid=i, prompt=list(p), max_new_tokens=n))
    ref = {r.rid: r.output for r in single.run()}
    assert sorted(ref) == list(range(len(prompts)))

    inproc = _drive_cluster(
        [tiny_engine_builder(**kw) for _ in range(2)], prompts, outs)
    wired = _drive_cluster(
        [tiny_engine_builder(**kw) for _ in range(2)], prompts, outs,
        wire="loopback")
    assert inproc == ref, (trial, paged, "inproc")
    assert wired == ref, (trial, paged, "loopback")

    if paged:
        # disaggregated: every KV-migration payload crosses the codec too
        disagg = _drive_cluster(
            [tiny_engine_builder(**kw) for _ in range(2)], prompts, outs,
            roles=["prefill", "decode"], wire="loopback")
        assert disagg == ref, (trial, "disagg_loopback")


# --------------------------------------------------------------------------
# the harness must catch injected faults
# --------------------------------------------------------------------------

def test_harness_catches_skipped_block_release(tiny_engine_builder,
                                               monkeypatch):
    """Injected fault: ``free_request`` forgets to decref (the classic
    leak — table dropped, references kept).  The end-state refcount sweep
    must trip; a harness that cannot catch this is decoration."""
    def leaky_free(self, rid):
        self.tables.pop(rid, None)
        self._reg_cursor.pop(rid, None)

    monkeypatch.setattr(BlockManager, "free_request", leaky_free)
    rng = np.random.RandomState(7)
    prompts, outs, _, _ = _gen_trace(rng)
    eng = tiny_engine_builder(max_batch=3, chunk_tokens=48, max_len=128,
                              prefill_bucket=16, block_size=16, paged=True)
    with pytest.raises((AssertionError, RuntimeError)):
        _drive(eng, prompts, outs, cancels={})


def test_harness_catches_budget_overrun(tiny_engine_builder, monkeypatch):
    """Injected fault: the packed planner stops charging verify width
    against the budget, overpacking the token axis.  The per-plan
    ``total_tokens <= chunk_tokens`` sweep must trip."""
    from repro.runtime import scheduler as SCH

    orig = SCH.Scheduler._next_packed

    def overpack(self, prefilling):
        plan = orig(self, prefilling)
        if plan is not None:
            for s in plan.segments:
                if s.kind == "prefill":
                    # pretend the budget was bigger than it is
                    s.n_tokens += self.cfg.chunk_tokens
                    plan.total_tokens += self.cfg.chunk_tokens
        return plan

    monkeypatch.setattr(SCH.Scheduler, "_next_packed", overpack)
    eng = tiny_engine_builder(max_batch=3, chunk_tokens=48, max_len=128,
                              prefill_bucket=16, block_size=16,
                              paged=True, packed=True)
    prompts = [[int(x) for x in np.arange(20)], [5, 6, 7]]
    with pytest.raises(AssertionError, match="budget"):
        _drive(eng, prompts, [3, 3], cancels={})
