"""Checkpoint manager: atomic roundtrip, trimming, async mode, elastic
restore across different meshes, and crash/resume determinism through the
train driver."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from conftest import SRC
from repro.checkpoint.manager import CheckpointManager


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.zeros((2, 2), jnp.bfloat16)}}


def test_roundtrip_and_trim(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, async_save=False)
    t = _tree()
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x * step, t))
    assert mgr.all_steps() == [2, 3]          # keep_last=2 trims step 1
    assert mgr.latest_step() == 3
    restored = mgr.restore(3, jax.eval_shape(lambda: t))
    np.testing.assert_allclose(restored["a"], np.asarray(t["a"]) * 3)
    assert restored["b"]["d"].dtype == jnp.bfloat16


def test_async_save_and_partial_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    t = _tree()
    mgr.save(7, t)
    mgr.wait()
    grown = dict(t, extra=jnp.full((3,), 9.0))   # model grew a param
    step, restored = mgr.restore_latest(grown)
    assert step == 7
    np.testing.assert_allclose(restored["extra"], 9.0)  # kept init value
    np.testing.assert_allclose(restored["a"], t["a"])


def test_elastic_restore_across_meshes(tmp_path):
    """Save sharded on (1,1); restore onto a different sharding layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    x = jnp.arange(64.0).reshape(8, 8)
    mgr.save(1, {"w": x})
    sh = NamedSharding(mesh, P("model", None))
    restored = mgr.restore(1, {"w": jax.eval_shape(lambda: x)},
                           {"w": sh})
    np.testing.assert_allclose(restored["w"], x)
    assert restored["w"].sharding == sh


def test_train_driver_crash_and_resume(tmp_path):
    """Simulated failure at step 6, restart resumes from the checkpoint and
    finishes; final loss matches an uninterrupted run (determinism)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    ck1 = str(tmp_path / "crash")
    ck2 = str(tmp_path / "clean")
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "qwen1.5-4b", "--reduced", "--steps", "12", "--ckpt-every", "4",
            "--batch", "2", "--seq", "64"]
    r1 = subprocess.run(args + ["--ckpt-dir", ck1, "--fail-at-step", "6"],
                        env=env, capture_output=True, text=True, timeout=560)
    assert r1.returncode == 42, r1.stdout + r1.stderr
    r2 = subprocess.run(args + ["--ckpt-dir", ck1], env=env,
                        capture_output=True, text=True, timeout=560)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from checkpoint step 6" in r2.stdout
    r3 = subprocess.run(args + ["--ckpt-dir", ck2], env=env,
                        capture_output=True, text=True, timeout=560)
    assert r3.returncode == 0

    def final_loss(out):
        for line in reversed(out.splitlines()):
            if "loss" in line:
                return float(line.split("loss")[1].split()[0])
        raise AssertionError(out)
    np.testing.assert_allclose(final_loss(r2.stdout), final_loss(r3.stdout),
                               rtol=1e-4)
