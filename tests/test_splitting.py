"""Deterministic tests for wave-aware smart-splitting (paper §3.1.1).

The hypothesis property tests live in test_splitting_props.py (skipped
cleanly when hypothesis is missing); these cases always run so splitting
never loses coverage."""
import math

from repro.core.splitting import (naive_split, pad_to_multiple, smart_split,
                                  split_sizes_for_batch, wave_count)


def test_paper_example_300_ctas_132_sms():
    # paper §3.1.1: 300 CTAs on 132 SMs -> smart split (132, 168)
    assert smart_split(300, 132) == (132, 168)
    # naive 150/150 costs 4 waves vs 3
    assert wave_count(150, 132) * 2 == 4
    assert wave_count(300, 132) == 3


def test_smart_split_invariants_grid():
    """Exhaustive small grid of the hypothesis invariants."""
    for unit in (1, 3, 8, 132, 256):
        for n in list(range(1, 4 * unit + 3)) + [10 * unit + 7]:
            s = smart_split(n, unit)
            if s is None:
                assert n < 2 * unit
                continue
            l1, l2 = s
            assert l1 + l2 == n
            assert l1 > 0 and l2 > 0
            assert l1 % unit == 0                  # prefix = full waves
            # wave conservation: the split never adds a wave
            assert wave_count(l1, unit) + wave_count(l2, unit) \
                == wave_count(n, unit)


def test_naive_split_adds_waves_smart_never():
    # 300 on unit 132: naive pays 4 waves, smart pays 3
    e1, e2 = naive_split(300)
    assert wave_count(e1, 132) + wave_count(e2, 132) == 4
    l1, l2 = smart_split(300, 132)
    assert wave_count(l1, 132) + wave_count(l2, 132) == 3


def test_split_sizes_for_batch_deterministic():
    # below min_tokens: no split
    assert split_sizes_for_batch(256, unit=256, min_tokens=512,
                                 row_multiple=1) is None
    # split point must respect lcm(unit, rows)
    s = split_sizes_for_batch(4096, unit=256, min_tokens=512, row_multiple=3)
    assert s is not None
    l1, l2 = s
    assert l1 + l2 == 4096
    assert l1 % math.lcm(256, 3) == 0
    # 2 rows of 1024 tokens, unit 256: clean halves
    assert split_sizes_for_batch(2048, unit=256, min_tokens=512,
                                 row_multiple=2) == (1024, 1024)


def test_pad_to_multiple_deterministic():
    for n, m, want in [(0, 8, 0), (1, 8, 8), (8, 8, 8), (9, 8, 16),
                       (255, 256, 256), (257, 256, 512)]:
        assert pad_to_multiple(n, m) == want
