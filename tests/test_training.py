"""Training-path tests: convergence, auto==manual grad sync, int8
compression, checkpoint resume determinism."""
import jax
import jax.numpy as jnp
import numpy as np

import pytest

from conftest import run_distributed


@pytest.mark.slow
def test_training_paths_agree_and_converge():
    run_distributed("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.build import build_model
from repro.training.train_step import (make_train_step,
                                       make_manual_sync_train_step)
from repro.training.optimizer import AdamWConfig
from repro.training.data import SyntheticLM
cfg = ModelConfig(name='tiny', family='dense', num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=64, dtype='float32')
pcfg = ParallelConfig(tokenweave=True, comm_mode='fused', remat=True,
                      split_unit=16, tokenweave_min_tokens=32,
                      dp_axes=('pod', 'data'), grad_compression='int8')
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
api = build_model(cfg, pcfg, tp=2)
data = SyntheticLM(vocab=64, seq_len=64, global_batch=8)
b0 = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
ocfg = AdamWConfig(lr=1e-2, warmup_steps=5)
step, init = make_train_step(api, mesh, b0, ocfg, dp_size=4)
params, opt = init(jax.random.PRNGKey(0))
losses = []
for i in range(10):
    b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
    params, opt, m = step(params, opt, b)
    losses.append(float(m['loss']))
assert losses[-1] < losses[0] - 0.3, losses
# manual sync == auto (same init, same batches)
step_m, init_m = make_manual_sync_train_step(api, mesh, b0, ocfg,
                                             compress_pod=False)
p1, o1 = init(jax.random.PRNGKey(7))
p2, o2 = init_m(jax.random.PRNGKey(7))
for i in range(3):
    b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
    p1, o1, m1 = step(p1, o1, b)
    p2, o2, m2 = step_m(p2, o2, b)
    np.testing.assert_allclose(float(m1['loss']), float(m2['loss']),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m1['grad_norm']),
                               float(m2['grad_norm']), rtol=1e-4)
d = max(jax.tree.leaves(jax.tree.map(
    lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                        - b_.astype(jnp.float32)))),
    p1, p2)))
assert d < 2e-4, d
# int8 compressed cross-pod reduce trains
step_c, init_c = make_manual_sync_train_step(api, mesh, b0, ocfg,
                                             compress_pod=True)
pc, oc, ef = init_c(jax.random.PRNGKey(0))
lc = []
for i in range(8):
    b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
    pc, oc, m, ef = step_c(pc, oc, ef, b)
    lc.append(float(m['loss']))
assert lc[-1] < lc[0] - 0.2, lc
print('PASS')
""", n_devices=8, timeout=560)


def test_compression_error_feedback_reduces_bias():
    """int8 psum with error feedback: accumulated mean error over repeated
    reductions stays near zero (EF corrects quantization bias)."""
    from repro.training.compression import compressed_psum
    import jax
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 1e-3

    def run(x):
        err = jnp.zeros_like(x)
        tot_true = jnp.zeros_like(x)
        tot_q = jnp.zeros_like(x)
        for i in range(20):
            xi = x * (1 + 0.1 * i)
            r, err = compressed_psum(xi, "pod", err)
            tot_q = tot_q + r
            tot_true = tot_true + xi
        return tot_q, tot_true

    f = jax.jit(jax.shard_map(run, mesh=mesh, in_specs=P(None),
                              out_specs=(P(None), P(None)),
                              check_vma=False))
    tq, tt = f(g)
    rel = float(jnp.linalg.norm(tq - tt) / jnp.linalg.norm(tt))
    assert rel < 0.02, rel   # EF keeps the running sum nearly unbiased


def test_synthetic_data_deterministic_and_sharded():
    from repro.training.data import SyntheticLM
    d = SyntheticLM(vocab=64, seq_len=32, global_batch=8)
    a = d.batch(3)
    b = d.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    h0 = d.batch(3, host_index=0, host_count=2)
    h1 = d.batch(3, host_index=1, host_count=2)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # next-token structure: labels are tokens shifted by one
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
